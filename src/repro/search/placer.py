"""``SearchPlacer``: the first ``Placer`` that composes other placers.

It takes any seed proposal -- a wrapped ``Placer`` (DreamShard, expert,
random, RNN) or an already-built ``Placement`` via ``refine`` -- and
improves it purely through the batched oracle path under an anytime
budget.  ``SearchConfig`` selects and parameterizes the strategy;
``strategy`` accepts a single family (``"lns"``, ``"evolution"``,
``"beam"``) or a ``"+"``-composed pipeline (``"beam+lns"`` runs beam
search, then polishes its best leaf with LNS) sharing one budget.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from repro import telemetry as tele
from repro.api.oracle import ensure_oracle
from repro.api.placement import BasePlacer, Placement, Placer
from repro.core.baselines import expert_place
from repro.data.tasks import Task
from repro.search import strategies as S
from repro.search.scoring import SearchScorer
from repro.sim.costsim import placement_digest

STRATEGIES = ("lns", "evolution", "beam")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Knobs for one ``SearchPlacer``; defaults match the benchmarks.

    Budget: ``budget_ms`` is a per-task wall-clock deadline checked
    between rounds; ``max_evals`` caps oracle candidate rows (seed
    measurement included) -- a deterministic meter that makes runs
    reproducible and, by construction, anytime-monotone.  Either may be
    ``None``; with both ``None`` set ``max_rounds`` or the search never
    stops.  A zero budget returns the seed placement bitwise.
    """

    strategy: str = "lns"          # family or "+"-composed pipeline
    budget_ms: float | None = 50.0
    max_evals: int | None = None
    max_rounds: int | None = None  # per strategy stage; None = budget-bound
    seed: int = 0                  # rng stream; mixed with task+seed digest
    # lns
    neighborhood: int = 64         # candidate rows proposed per round
    swap_fraction: float = 0.25    # share of the round spent on swaps
    # evolution
    population: int = 32
    elites: int = 4
    mutations: int = 2             # k random reassignments per child
    crossover_rate: float = 0.5
    tournament: int = 3
    # beam
    beam_width: int = 8

    def stages(self) -> tuple[str, ...]:
        names = tuple(s.strip() for s in self.strategy.split("+") if s)
        for n in names:
            if n not in STRATEGIES:
                raise ValueError(
                    f"unknown search strategy {n!r}; "
                    f"expected one of {STRATEGIES} (optionally '+'-composed)")
        if not names:
            raise ValueError("SearchConfig.strategy selected no stages")
        return names


class SearchPlacer(BasePlacer):
    """Refine a seed placer's proposals through the batched oracle.

    ``seed_placer=None`` seeds from the greedy size-balance expert (the
    cheapest deterministic proposal).  ``agent`` (a trained
    ``DreamShard``) is required only by the ``"beam"`` strategy, which
    scores partial placements with the agent's cost network.
    """

    def __init__(self, oracle, seed_placer: Placer | None = None,
                 config: SearchConfig | None = None, agent=None,
                 name: str | None = None):
        self.oracle = ensure_oracle(oracle)
        self.seed_placer = seed_placer
        self.config = config if config is not None else SearchConfig()
        self.config.stages()           # validate eagerly, not per task
        if "beam" in self.config.stages() and agent is None:
            raise ValueError("strategy 'beam' needs a trained DreamShard "
                             "agent (its cost network scores the beam)")
        self.agent = agent
        seed_name = seed_placer.name if seed_placer is not None else "expert"
        self.name = name if name is not None else \
            f"search[{self.config.strategy}]({seed_name})"
        self.last_scorer: SearchScorer | None = None   # introspection

    # ---- seeding ------------------------------------------------------------

    def _seed_placement(self, task: Task) -> Placement:
        if self.seed_placer is not None:
            return self.seed_placer.place(task)
        a = expert_place(task.raw_features, task.n_devices,
                         self.oracle.mem_capacity_gb, "size")
        return self._wrap(task, a)

    # ---- refinement ---------------------------------------------------------

    def refine(self, task: Task, placement: Placement) -> Placement:
        """Improve one seed ``Placement`` within the anytime budget.

        Returns a placement whose measured cost is <= the seed's; with
        an exhausted-at-entry budget (``budget_ms=0`` / ``max_evals=0``)
        the seed comes back bitwise (same assignment and plan objects),
        relabeled with this placer's name.
        """
        sp = tele.span("search.refine", strategy=self.config.strategy,
                       M=len(task.raw_features),
                       n_devices=task.n_devices)
        with sp:
            out = self._refine_impl(task, placement)
            if self.last_scorer is not None:
                sp.set(cost_ms=out.est_cost_ms,
                       evals=self.last_scorer.evals,
                       hardware_evals=self.last_scorer.hardware_evals)
            return out

    def _refine_impl(self, task: Task, placement: Placement) -> Placement:
        cfg = self.config
        spec = placement.sharding
        if spec is None:
            a0 = np.asarray(placement.assignment, dtype=np.int64)
            features = task.raw_features
        else:
            # shard rows ARE table rows over the expanded pseudo-tables:
            # lns/evolution propose shard moves/swaps unchanged.  Beam is
            # a whole-table MDP (the agent's cost net consumes per-table
            # state), so it cannot refine a sharded placement.
            if "beam" in cfg.stages():
                raise ValueError(
                    "strategy 'beam' is whole-table only and cannot refine "
                    "a column-sharded placement; use 'lns'/'evolution'")
            from repro.sharding import shard_features
            a0 = np.asarray(placement.shard_assignment, dtype=np.int64)
            features = shard_features(task.raw_features, spec)
        scorer = SearchScorer(self.oracle, task, budget_ms=cfg.budget_ms,
                              max_evals=cfg.max_evals, sharding=spec)
        self.last_scorer = scorer
        if task.n_devices <= 1 or scorer.out_of_budget():
            return dataclasses.replace(placement, strategy=self.name)

        # one deterministic stream per (config seed, task, seed placement):
        # same seed + same budget replays identically, and a larger
        # max_evals replays the smaller run's rounds then keeps going
        # (for a sharded seed the digest runs over the expanded features,
        # which for K = 1 equal the raw features bitwise)
        rng = np.random.default_rng(
            [cfg.seed, placement_digest(features, a0, task.n_devices)])
        scorer.filter_new(a0[None])
        seed_costs, seed_results = scorer.score(a0[None])
        incumbent = S.Incumbent(assignment=a0, cost=float(seed_costs[0]),
                                result=seed_results[0])
        enforce_legal = bool(scorer.legal(a0[None])[0])

        for stage in cfg.stages():
            if scorer.out_of_budget():
                break
            if stage == "lns":
                S.refine_lns(scorer, rng, cfg, incumbent, enforce_legal)
            elif stage == "evolution":
                S.refine_evolution(scorer, rng, cfg, incumbent,
                                   enforce_legal)
            else:
                S.refine_beam(scorer, rng, cfg, incumbent, enforce_legal,
                              self.agent)

        if np.array_equal(incumbent.assignment, a0):
            # keep the seed's plan object: bitwise-stable when search
            # found nothing better (or the seed was already optimal)
            return dataclasses.replace(
                placement, strategy=self.name,
                est_cost_ms=incumbent.cost if np.isfinite(incumbent.cost)
                else placement.est_cost_ms,
                candidates=placement.candidates + scorer.evals - 1,
                oracle_evals=placement.oracle_evals + scorer.hardware_evals)
        return self._wrap(
            task, incumbent.assignment, est_cost_ms=incumbent.cost,
            candidates=placement.candidates + scorer.evals - 1,
            oracle_evals=placement.oracle_evals + scorer.hardware_evals,
            sharding=spec)

    # ---- Placer protocol ----------------------------------------------------

    def place(self, task: Task) -> Placement:
        return self.refine(task, self._seed_placement(task))

    def place_many(self, tasks: Iterable[Task]) -> list[Placement]:
        tasks = list(tasks)
        if self.seed_placer is not None:
            seeds = self.seed_placer.place_many(tasks)   # batched decode
        else:
            seeds = [self._seed_placement(t) for t in tasks]
        return [self.refine(t, s) for t, s in zip(tasks, seeds)]
