"""Search-augmented placement: refine any seed proposal through the
batched oracle under an anytime budget.

Public surface:

* ``SearchPlacer``  -- a ``Placer`` that composes a seed placer with a
  search strategy (also re-exported from ``repro.api``);
* ``SearchConfig``  -- strategy selection + budget + per-family knobs;
* ``SearchScorer``  -- the budget-metered batched scoring seam, for
  building new strategies on top of.
"""

from repro.search.placer import STRATEGIES, SearchConfig, SearchPlacer
from repro.search.scoring import SearchScorer

__all__ = ["STRATEGIES", "SearchConfig", "SearchPlacer", "SearchScorer"]
