"""End-to-end driver: train a DLRM recommender for a few hundred steps on
CPU with the distributed table-parallel embedding path, comparing a
DreamShard placement against a random placement end to end.

The model is ~100M params at full table sizes; on CPU we shrink hash sizes
(CLI flags) while keeping the full pipeline: synthetic click-through data
-> ``Placer`` -> ``Placement`` (assignment + physical plan) -> sharded
embedding + dense MLPs -> row-wise Adagrad on arenas + Adam on the dense
nets.

  PYTHONPATH=src python examples/train_dlrm_end2end.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import RandomPlacer, SimOracle
from repro.core import features as F
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.synthetic import make_dlrm_pool
from repro.data.tasks import Task, make_benchmark_suite
from repro.embedding import sharded as E
from repro.models.dlrm import DLRM, DLRMConfig
from repro.optim import adam, apply_updates, rowwise_adagrad


def synth_batch(rng, plan, raw, batch, n_dense, pool_max=6):
    """Synthetic CTR batch: zipf-ish indices per table + dense features."""
    M = raw.shape[0]
    hashes = raw[:, F.HASH_SIZE].astype(np.int64)
    pools = np.minimum(raw[:, F.POOLING].astype(np.int64) + 1, pool_max)
    idx = np.full((batch, M, pool_max), -1, np.int32)
    for t in range(M):
        draws = rng.zipf(1.5, size=(batch, pools[t])) % hashes[t]
        idx[:, t, :pools[t]] = draws
    dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
    labels = (rng.random(batch) < 0.3).astype(np.float32)
    return (jnp.asarray(E.group_indices(plan, idx)), jnp.asarray(dense),
            jnp.asarray(labels))


def train_with_placement(name, task, placement, args, oracle):
    plan = placement.plan                     # physical layout, ready-made
    raw = task.raw_features
    cost = oracle.evaluate(raw, placement.assignment,
                           placement.n_devices).overall
    cfg = DLRMConfig(n_dense_features=13, embed_dim=plan.dim,
                     bottom_mlp=(128, 64), top_mlp=(256, 128, 64),
                     n_tables=raw.shape[0])
    model = DLRM(cfg, plan)
    params = model.init_params(jax.random.PRNGKey(0))
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))

    emb_opt = rowwise_adagrad(0.05)
    dense_opt = adam(1e-3)
    emb_state = emb_opt.init({"arenas": params["arenas"]})
    dense_state = dense_opt.init({k: params[k] for k in ("bottom", "top")})
    def lookup(a, b, i):
        return E.lookup_unsharded(a, plan.base_rows, i, plan)

    @jax.jit
    def step(params, emb_state, dense_state, gidx, dense, labels):
        def loss_fn(p):
            return DLRM.loss(model.forward(p, dense, gidx, lookup), labels)
        loss, g = jax.value_and_grad(loss_fn)(params)
        eu, emb_state = emb_opt.update({"arenas": g["arenas"]}, emb_state)
        du, dense_state = dense_opt.update(
            {k: g[k] for k in ("bottom", "top")}, dense_state)
        params = {**apply_updates({k: params[k] for k in ("bottom", "top")},
                                  du),
                  **apply_updates({"arenas": params["arenas"]}, eu)}
        return params, emb_state, dense_state, loss

    rng = np.random.default_rng(0)
    losses, t0 = [], time.perf_counter()
    for i in range(args.steps):
        gidx, dense, labels = synth_batch(rng, plan, raw, args.batch, 13)
        params, emb_state, dense_state, loss = step(
            params, emb_state, dense_state, gidx, dense, labels)
        losses.append(float(loss))
        if i % max(args.steps // 5, 1) == 0:
            print(f"  [{name}] step {i:4d} loss {np.mean(losses[-20:]):.4f}")
    wall = time.perf_counter() - t0
    print(f"  [{name}] {n_params / 1e6:.1f}M params, "
          f"placement cost {cost:.2f} ms/iter (simulated), "
          f"final loss {np.mean(losses[-20:]):.4f}, wall {wall:.1f}s")
    assert np.isfinite(losses).all()
    return cost, losses[-1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--tables", type=int, default=24)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--max-rows", type=int, default=20000)
    args = ap.parse_args()

    pool = make_dlrm_pool(seed=0)
    oracle = SimOracle(seed=0)
    raw = pool[: args.tables].copy()
    raw[:, F.HASH_SIZE] = np.clip(raw[:, F.HASH_SIZE], 100, args.max_rows)
    raw[:, F.TABLE_SIZE_GB] = F.table_size_gb(raw[:, F.DIM],
                                              raw[:, F.HASH_SIZE])
    task = Task.of(raw, args.shards, name="dlrm-end2end")

    print("training DreamShard placer (small budget)...")
    train_tasks, _ = make_benchmark_suite(pool, args.tables, args.shards,
                                          n_tasks=8)
    agent = DreamShard(train_tasks, oracle,
                       DreamShardConfig(n_iterations=5, n_cost=150, n_rl=10))
    agent.train()
    ds_placement = agent.as_placer().place(task)
    rnd_placement = RandomPlacer(oracle, seed=0).place(task)

    print("\n== DLRM end-to-end with DreamShard placement ==")
    c1, _ = train_with_placement("dreamshard", task, ds_placement, args,
                                 oracle)
    print("== DLRM end-to-end with random placement ==")
    c2, _ = train_with_placement("random", task, rnd_placement, args, oracle)
    print(f"\nembedding step cost: dreamshard {c1:.2f} ms vs random "
          f"{c2:.2f} ms  ({(c2 / c1 - 1) * 100:+.1f}%)")


if __name__ == "__main__":
    main()
