"""Beyond-paper: DreamShard for MoE *expert* placement.

Experts are the MoE analogue of embedding tables: per-expert compute load
follows the router distribution (heavy-tailed, like pooling factors), the
all-to-all dispatch volume follows per-shard routed-token counts (like
embedding dim-sums), and experts fused on one shard share launch overhead.
We encode each expert of an olmoe-style 64-expert layer as a 21-feature
"table" (d_ff -> dim, routed-token share -> pooling factor, parameter
bytes -> size) and reuse the UNMODIFIED DreamShard pipeline to balance
expert-parallel shards, vs the standard round-robin expert placement.

  PYTHONPATH=src python examples/moe_expert_placement.py
"""

import numpy as np

from repro.core import baselines as B
from repro.core import features as F
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import Task
from repro.sim.costsim import CostSimulator


def experts_as_tables(n_experts, d_model, d_ff, rng):
    """Encode MoE experts in the 21-feature table schema."""
    # routed-token share: heavy-tailed router (the load-balance problem)
    share = rng.dirichlet(np.full(n_experts, 0.3))
    dim = np.full(n_experts, d_ff / 64.0)            # comm volume proxy
    hash_size = np.full(n_experts, d_model * 3.0)    # param rows proxy
    pooling = share * n_experts * 16.0               # compute load proxy
    dist = np.tile(np.eye(F.NUM_DIST_BINS)[8], (n_experts, 1))
    return F.pack_features(dim, hash_size, pooling, dist), share


def main():
    rng = np.random.default_rng(0)
    n_experts, d_model, d_ff, n_shards = 64, 2048, 1024, 8

    # build a pool of "expert tables" across many simulated routers
    pools = [experts_as_tables(n_experts, d_model, d_ff,
                               np.random.default_rng(s))[0]
             for s in range(12)]
    sim = CostSimulator(seed=0)
    train_tasks = [Task(raw_features=p, n_devices=n_shards,
                        table_ids=np.arange(n_experts),
                        name=f"moe-{i}") for i, p in enumerate(pools[:8])]

    print("training DreamShard on expert-placement tasks...")
    agent = DreamShard(train_tasks, sim,
                       DreamShardConfig(n_iterations=6, n_cost=150, n_rl=10))
    agent.train()

    print("\n== unseen routers (held-out) ==")
    for i, raw in enumerate(pools[8:]):
        ds = agent.place(raw, n_shards)
        rr = np.arange(n_experts) % n_shards          # round-robin default
        greedy = B.expert_place(raw, n_shards, sim.spec.mem_capacity_gb,
                                "lookup")
        c_ds = sim.evaluate(raw, ds, n_shards).overall
        c_rr = sim.evaluate(raw, rr, n_shards).overall
        c_gr = sim.evaluate(raw, greedy, n_shards).overall
        print(f"  router {i}: round-robin {c_rr:6.2f}  greedy {c_gr:6.2f}  "
              f"dreamshard {c_ds:6.2f}  ({(c_rr / c_ds - 1) * 100:+.1f}% vs rr)")


if __name__ == "__main__":
    main()
