"""Beyond-paper: DreamShard for MoE *expert* placement.

Experts are the MoE analogue of embedding tables: per-expert compute load
follows the router distribution (heavy-tailed, like pooling factors), the
all-to-all dispatch volume follows per-shard routed-token counts (like
embedding dim-sums), and experts fused on one shard share launch overhead.
We encode each expert of an olmoe-style 64-expert layer as a 21-feature
"table" (d_ff -> dim, routed-token share -> pooling factor, parameter
bytes -> size) and reuse the UNMODIFIED DreamShard pipeline to balance
expert-parallel shards, vs the standard round-robin expert placement.

  PYTHONPATH=src python examples/moe_expert_placement.py
"""

import numpy as np

from repro.api import ExpertPlacer, SimOracle
from repro.core import features as F
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import Task


def experts_as_tables(n_experts, d_model, d_ff, rng):
    """Encode MoE experts in the 21-feature table schema."""
    # routed-token share: heavy-tailed router (the load-balance problem)
    share = rng.dirichlet(np.full(n_experts, 0.3))
    dim = np.full(n_experts, d_ff / 64.0)            # comm volume proxy
    hash_size = np.full(n_experts, d_model * 3.0)    # param rows proxy
    pooling = share * n_experts * 16.0               # compute load proxy
    dist = np.tile(np.eye(F.NUM_DIST_BINS)[8], (n_experts, 1))
    return F.pack_features(dim, hash_size, pooling, dist), share


def main():
    n_experts, d_model, d_ff, n_shards = 64, 2048, 1024, 8

    # build a pool of "expert tables" across many simulated routers
    pools = [experts_as_tables(n_experts, d_model, d_ff,
                               np.random.default_rng(s))[0]
             for s in range(12)]
    oracle = SimOracle(seed=0)
    train_tasks = [Task.of(p, n_shards, name=f"moe-{i}")
                   for i, p in enumerate(pools[:8])]

    print("training DreamShard on expert-placement tasks...")
    agent = DreamShard(train_tasks, oracle,
                       DreamShardConfig(n_iterations=6, n_cost=150, n_rl=10))
    agent.train()

    print("\n== unseen routers (held-out) ==")
    test_tasks = [Task.of(p, n_shards, name=f"moe-test-{i}")
                  for i, p in enumerate(pools[8:])]
    ds_placements = agent.as_placer().place_many(test_tasks)   # one compile
    greedy_placer = ExpertPlacer(oracle, "lookup")
    for i, (t, p) in enumerate(zip(test_tasks, ds_placements)):
        raw = t.raw_features
        rr = np.arange(n_experts) % n_shards          # round-robin default
        c_ds = oracle.evaluate(raw, p.assignment, n_shards).overall
        c_rr = oracle.evaluate(raw, rr, n_shards).overall
        c_gr = oracle.evaluate(raw, greedy_placer.place(t).assignment,
                               n_shards).overall
        print(f"  router {i}: round-robin {c_rr:6.2f}  greedy {c_gr:6.2f}  "
              f"dreamshard {c_ds:6.2f}  ({(c_rr / c_ds - 1) * 100:+.1f}% vs rr)")


if __name__ == "__main__":
    main()
