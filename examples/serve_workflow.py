"""Placement serving workflow: front a trained agent with
``PlacementService`` -- digest-keyed placement cache, micro-batch
admission, and drift-triggered re-placement -- and replay a synthetic
drifting request stream through it.

  PYTHONPATH=src python examples/serve_workflow.py
"""

import numpy as np

from repro.api import PlacementService, ServeConfig, SimOracle
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.synthetic import make_dlrm_pool
from repro.data.tasks import sample_tasks, split_pool
from repro.data.traffic import TrafficConfig, make_trace


def main():
    pool = make_dlrm_pool(seed=0)
    oracle = SimOracle(seed=0)
    train_ids, _ = split_pool(pool, seed=0)
    train_tasks = sample_tasks(pool, train_ids, 20, 4, 8, seed=0)

    print("training a small DreamShard agent...")
    agent = DreamShard(train_tasks, oracle, DreamShardConfig(
        n_iterations=3, n_collect=6, n_cost=100, n_batch=32, n_rl=5,
        n_episode=10, inference_candidates=8))
    agent.train()

    # a few recurring jobs, Zipf-skewed popularity, drifting histograms
    trace = make_trace(pool, TrafficConfig(
        n_jobs=6, n_tables=20, n_devices=4, n_requests=300,
        drift=0.8, tail_jobs=3, seed=0))

    svc = PlacementService(agent, config=ServeConfig(
        max_wait_ms=2.0, max_batch=8,     # micro-batch admission window
        drift_threshold=0.05,             # max per-table TV distance
        ewma_alpha=0.3,                   # traffic-estimate smoothing
        migration_ms_per_gb=25.0,         # moves must pay for transfer
        replace_max_evals=64))

    print(f"replaying {len(trace)} requests...")
    served = []
    for r in trace:
        served += svc.submit(r.raw_features, r.n_devices, tag=r.job)
    served += svc.flush()                 # drain stragglers

    stats = svc.stats()
    hits = [s.latency_ms for s in served
            if s.source == "cache" and not s.replaced]
    decodes = [s.latency_ms for s in served if s.source == "decode"]
    print(f"\nserved {len(served)} requests; "
          f"hit rate {stats['hit_rate']:.1%} "
          f"({stats['coalesced']} coalesced into "
          f"{stats['decode_batches']} decode batches)")
    print(f"warm-hit latency p50 {np.percentile(hits, 50):.3f} ms, "
          f"p99 {np.percentile(hits, 99):.3f} ms; "
          f"decode p50 {np.percentile(decodes, 50):.1f} ms")
    print(f"drift re-placements: {stats['replace_events']} triggers, "
          f"{stats['migrations']} moved tables, "
          f"{stats['bytes_moved_gb']:.3f} GB migrated")

    # every cached entry keeps serving post-re-placement: same digest,
    # fresher placement
    one = max(svc.cache.entries(), key=lambda e: e.replaces)
    print(f"hottest entry: {one.requests} requests, "
          f"{one.replaces} re-placements, "
          f"assignment {one.placement.assignment.tolist()}")


if __name__ == "__main__":
    main()
