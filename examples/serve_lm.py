"""Serve a small LM with batched requests: prefill + decode loop using the
same step builders the multi-pod dry-run lowers (reduced h2o-danube config
on CPU, greedy sampling over batched prompts).

  PYTHONPATH=src python examples/serve_lm.py --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.launch import steps as ST


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch).resolve(1)
    model = ST.build_model(cfg, remat=False, q_chunk=16, kv_chunk=16)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    capacity = args.prompt_len + args.tokens
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    prefill = jax.jit(lambda p, t: model.prefill(p, t, capacity=capacity))
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    out = [next_tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = decode(params, cache, next_tok)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms (incl. compile)")
    print(f"decode:  {t_decode / max(args.tokens - 1, 1) * 1e3:.2f} ms/token")
    for b in range(args.batch):
        print(f"  request {b}: {gen[b, :16].tolist()} ...")
    assert gen.shape == (args.batch, args.tokens)
    assert (gen >= 0).all() and (gen < cfg.vocab_padded).all()


if __name__ == "__main__":
    main()
