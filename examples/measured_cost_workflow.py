"""Measured-cost workflow: calibrate -> train -> place.

The sim-to-real loop in three steps: (1) run the offline micro-benchmark
calibration once (here a tiny in-process smoke sweep; in production
``python -m repro.profiling.calibrate`` persists the artifact), (2) train
DreamShard against a ``MeasuredOracle`` that interpolates the measured
costs with zero kernel launches per evaluate, (3) place unseen tasks and
read the measured cost decomposition.

  PYTHONPATH=src python examples/measured_cost_workflow.py
"""

import numpy as np

from repro.api import MeasuredOracle, evaluate_placer, make_baseline_placers
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.synthetic import make_dlrm_pool
from repro.data.tasks import make_benchmark_suite
from repro.profiling import CalibrationTable, load_or_none


def main():
    # 1. calibrate (reuse the persisted artifact when one exists --
    #    `python -m repro.profiling.calibrate --smoke` writes it)
    table = load_or_none()
    if table is None:
        print("calibrating (smoke grid; persist one with "
              "`python -m repro.profiling.calibrate`)...")
        table = CalibrationTable.measure(
            dims=(16, 64, 256), rows=(256, 4096), batches=(64,),
            poolings=(2, 8), use_pallas=False, repeats=2)
    print(table.summary())

    # 2. train against measured costs -- same trainer, different oracle
    pool = make_dlrm_pool(seed=0)
    train_tasks, test_tasks = make_benchmark_suite(
        pool, n_tables=20, n_devices=4, n_tasks=10)
    oracle = MeasuredOracle(table)
    agent = DreamShard(train_tasks, oracle,
                       DreamShardConfig(n_iterations=6, n_collect=10,
                                        n_cost=150, n_rl=8))
    agent.train(eval_tasks=test_tasks[:3], log=True)

    # 3. place unseen tasks; every number below is interpolated from the
    #    calibration artifact, not simulated
    placers = make_baseline_placers(oracle, seed=0)
    placers["dreamshard"] = agent.as_placer()
    print("\n== held-out tasks, measured cost ==")
    for name, placer in placers.items():
        cost = evaluate_placer(MeasuredOracle(table), test_tasks, placer)
        print(f"  {name:12s} {cost:8.3f} ms")

    t = test_tasks[0]
    res = oracle.evaluate(t.raw_features, placers["dreamshard"]
                          .place(t).assignment, t.n_devices)
    with np.printoptions(precision=3):
        print(f"\nmeasured decomposition for task 0: overall "
              f"{res.overall:.3f} ms\n  fwd_comp {res.fwd_comp}\n"
              f"  bwd_comp {res.bwd_comp}\n  bwd_comm {res.bwd_comm}")
    print(f"oracle consumed {oracle.num_evaluations} evaluations, "
          "0 kernel launches after calibration")


if __name__ == "__main__":
    main()
