"""Quickstart: train a DreamShard placer on synthetic DLRM tables and
compare it against the human-expert strategies on unseen tables.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import baselines as B
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.synthetic import make_dlrm_pool
from repro.data.tasks import make_benchmark_suite
from repro.sim.costsim import CostSimulator


def main():
    pool = make_dlrm_pool(seed=0)                 # 856 synthetic tables
    sim = CostSimulator(seed=0)                   # the "hardware"
    train_tasks, test_tasks = make_benchmark_suite(
        pool, n_tables=50, n_devices=4, n_tasks=20)

    print("training DreamShard on DLRM-50 (4 GPUs)...")
    agent = DreamShard(train_tasks, sim, DreamShardConfig())
    agent.train(eval_tasks=test_tasks[:5], log=True)

    print("\n== held-out test tasks (unseen tables) ==")
    rng = np.random.default_rng(0)
    cap = sim.spec.mem_capacity_gb
    rows = {"random": lambda t: B.random_place(t.raw_features, t.n_devices,
                                               cap, rng)}
    for s in B.EXPERT_STRATEGIES:
        rows[s] = lambda t, s=s: B.expert_place(t.raw_features, t.n_devices,
                                                cap, s)
    rows["dreamshard"] = lambda t: agent.place(t.raw_features, t.n_devices)
    base = None
    for name, fn in rows.items():
        cost = np.mean([sim.evaluate(t.raw_features, fn(t),
                                     t.n_devices).overall
                        for t in test_tasks])
        base = base or cost
        print(f"  {name:12s} {cost:7.2f} ms   ({(base / cost - 1) * 100:+.1f}%"
              " vs random)")

    # one concrete placement, end to end
    t = test_tasks[0]
    placement = agent.place(t.raw_features, t.n_devices)
    print(f"\nplacement for task 0 ({t.n_tables} tables on"
          f" {t.n_devices} devices): {placement.tolist()}")
    print(f"cost: {sim.evaluate(t.raw_features, placement, t.n_devices).overall:.2f} ms")


if __name__ == "__main__":
    main()
