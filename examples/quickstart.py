"""Quickstart: train a DreamShard placer on synthetic DLRM tables and
compare it against the human-expert strategies on unseen tables -- all
through the unified ``repro.api`` placement interface.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.api import SimOracle, evaluate_placer, make_baseline_placers
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.synthetic import make_dlrm_pool
from repro.data.tasks import make_benchmark_suite


def main():
    pool = make_dlrm_pool(seed=0)                 # 856 synthetic tables
    oracle = SimOracle(seed=0)                    # the "hardware"
    train_tasks, test_tasks = make_benchmark_suite(
        pool, n_tables=50, n_devices=4, n_tasks=20)

    print("training DreamShard on DLRM-50 (4 GPUs)...")
    agent = DreamShard(train_tasks, oracle, DreamShardConfig())
    agent.train(eval_tasks=test_tasks[:5], log=True)

    print("\n== held-out test tasks (unseen tables) ==")
    placers = make_baseline_placers(oracle, seed=0)
    placers["dreamshard"] = agent.as_placer()     # batched PlacementSession
    base = None
    for name, placer in placers.items():
        cost = evaluate_placer(oracle, test_tasks, placer)
        base = base or cost
        print(f"  {name:12s} {cost:7.2f} ms   ({(base / cost - 1) * 100:+.1f}%"
              " vs random)")

    # one concrete placement, end to end, with provenance + physical plan
    t = test_tasks[0]
    p = placers["dreamshard"].place(t)
    measured = oracle.evaluate(t.raw_features, p.assignment,
                               t.n_devices).overall
    print(f"\nplacement for task 0 ({t.n_tables} tables on"
          f" {t.n_devices} devices): {p.assignment.tolist()}")
    print(f"strategy={p.strategy} candidates={p.candidates} "
          f"estimated {p.est_cost_ms:.2f} ms, measured {measured:.2f} ms; "
          f"plan: {p.plan.n_shards} shards x {p.plan.k_max} table slots")


if __name__ == "__main__":
    main()
